"""Jit'd public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale=None, interpret: bool = False):
    return _kernel(q, k_pages, v_pages, block_tables, lengths, scale=scale,
                   interpret=interpret or not _on_tpu())


__all__ = ["paged_attention", "paged_attention_ref"]
