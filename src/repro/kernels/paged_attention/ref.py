"""Pure-jnp oracle for paged attention (ragged mixed prefill+decode).

The general entry point is :func:`paged_attention_mixed_ref`: every batch
lane carries ``q_len >= 1`` query rows (a decode lane is ``q_len=1``, a
prefill chunk is ``q_len=chunk``) and a per-row *sequence position*;
causality is enforced inside the page walk by masking every key slot past
the row's position.  The classic single-token decode oracle
(:func:`paged_attention_ref`) is the ``q_len=1`` special case.

Pages may optionally be int8-quantized with per-page-row scales
(``[P, page, KV]``): gathered pages are dequantized before the score
matmul, so only the pages a lane actually touches pay the dequant.
"""
from __future__ import annotations

import jax.numpy as jnp

_NEG_INF = -2.0e38


def _gather_pages(pages, block_tables, scales, out_dtype):
    """pages[block_tables] -> [B, PPS*page, KV, hd], dequantized."""
    b, pps = block_tables.shape
    page = pages.shape[1]
    kv, hd = pages.shape[2], pages.shape[3]
    g = pages[block_tables]                     # [B, PPS, page, KV, hd]
    g = g.reshape(b, pps * page, kv, hd).astype(jnp.float32)
    if scales is not None:
        s = scales[block_tables].reshape(b, pps * page, kv)
        g = g * s.astype(jnp.float32)[..., None]
    return g.astype(out_dtype)


def paged_attention_mixed_ref(q, k_pages, v_pages, block_tables, q_positions,
                              *, scale=None, k_scales=None, v_scales=None):
    """Ragged multi-row attention over a paged KV cache.

    q            [B, Q, H, hd]      (Q query rows per lane; pad rows are
                                     harmless — give them position 0)
    k_pages      [P, page, KV, hd]  (global page pool; int8 if *_scales)
    v_pages      [P, page, KV, hd]
    block_tables [B, PPS] int32     (page ids per sequence)
    q_positions  [B, Q] int32       (sequence position of each query row;
                                     row i attends key slots t <= pos[i])
    k_scales     [P, page, KV] f32  (optional int8 per-page-row scales)
    v_scales     [P, page, KV] f32
    Returns      [B, Q, H, hd]
    """
    b, qn, h, hd = q.shape
    page = k_pages.shape[1]
    kv = k_pages.shape[2]
    g = h // kv
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    k = _gather_pages(k_pages, block_tables, k_scales, jnp.float32)
    v = _gather_pages(v_pages, block_tables, v_scales, jnp.float32)
    t = k.shape[1]
    qg = q.reshape(b, qn, kv, g, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg.astype(jnp.float32), k) * scale
    pos_k = jnp.arange(t, dtype=jnp.int32)
    mask = pos_k[None, None] <= q_positions[:, :, None]      # [B, Q, T]
    mask = mask[:, None, None]                               # [B,1,1,Q,T]
    s = jnp.where(mask, s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    p = p / (jnp.sum(p, axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bkgqt,btkh->bqkgh", p, v)
    return out.reshape(b, qn, h, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale=None, k_scales=None, v_scales=None):
    """Single-token decode attention over a paged KV cache (q_len=1 case).

    q            [B, H, hd]
    lengths      [B] int32  (tokens in each sequence; >= 1)
    Returns      [B, H, hd]
    """
    out = paged_attention_mixed_ref(
        q[:, None], k_pages, v_pages, block_tables,
        (lengths - 1)[:, None].astype(jnp.int32), scale=scale,
        k_scales=k_scales, v_scales=v_scales)
    return out[:, 0]
