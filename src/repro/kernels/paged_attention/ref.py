"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax.numpy as jnp

_NEG_INF = -2.0e38


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale=None):
    """Single-token decode attention over a paged KV cache.

    q            [B, H, hd]
    k_pages      [P, page, KV, hd]   (global page pool)
    v_pages      [P, page, KV, hd]
    block_tables [B, pages_per_seq] int32  (page ids per sequence)
    lengths      [B] int32                 (tokens in each sequence)
    Returns      [B, H, hd]
    """
    b, h, hd = q.shape
    page = k_pages.shape[1]
    kv = k_pages.shape[2]
    g = h // kv
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    k = k_pages[block_tables]          # [B, PPS, page, KV, hd]
    v = v_pages[block_tables]
    b_, pps = block_tables.shape
    k = k.reshape(b, pps * page, kv, hd)
    v = v.reshape(b, pps * page, kv, hd)
    qg = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(pps * page)
    mask = pos[None] < lengths[:, None]              # [B, T]
    s = jnp.where(mask[:, None, None], s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask[:, None, None], p, 0.0)
    p = p / (jnp.sum(p, axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)
