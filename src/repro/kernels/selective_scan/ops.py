"""Jit'd public wrapper for the Mamba selective scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.selective_scan.kernel import selective_scan as _kernel
from repro.kernels.selective_scan.ref import selective_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(x, delta, a, b, c, d, h0=None, *, block_d: int = 256,
                   chunk: int = 128, interpret: bool = False):
    return _kernel(x, delta, a, b, c, d, h0, block_d=block_d, chunk=chunk,
                   interpret=interpret or not _on_tpu())


__all__ = ["selective_scan", "selective_scan_ref"]
