"""Pure-jnp oracle for the Mamba selective scan."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def selective_scan_ref(x, delta, a, b, c, d, h0=None):
    """Sequential reference of  h_t = exp(delta_t A) h_{t-1} + delta_t B_t x_t;
    y_t = C_t h_t + D x_t.

    x      [B, S, D]      input activations (post conv)
    delta  [B, S, D]      softplus'd timestep
    a      [D, N]         negative-definite state matrix (diag, = -exp(A_log))
    b      [B, S, N]      input matrix
    c      [B, S, N]      output matrix
    d      [D]            skip
    h0     [B, D, N]      initial state (optional)
    Returns (y [B,S,D], h_final [B,D,N]).
    """
    xb, s, dd = x.shape
    n = a.shape[1]
    x = np.asarray(x, np.float32)
    delta = np.asarray(delta, np.float32)
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    c = np.asarray(c, np.float32)
    d = np.asarray(d, np.float32)
    h = np.zeros((xb, dd, n), np.float32) if h0 is None \
        else np.asarray(h0, np.float32).copy()
    ys = np.zeros((xb, s, dd), np.float32)
    for t in range(s):
        da = np.exp(delta[:, t, :, None] * a[None])            # [B,D,N]
        dbx = delta[:, t, :, None] * b[:, t, None, :] * x[:, t, :, None]
        h = da * h + dbx
        ys[:, t] = np.einsum("bdn,bn->bd", h, c[:, t]) + d * x[:, t]
    return jnp.asarray(ys), jnp.asarray(h)
