"""Pallas TPU selective scan (Mamba recurrence), chunked along the sequence.

TPU adaptation notes (vs the CUDA selective-scan kernel):
  * the GPU kernel parallelises over (batch, channel-block) thread blocks
    and keeps the recurrent state in registers; on TPU the state tile
    [block_d, N] lives in VMEM scratch and persists across the innermost
    (sequence-chunk) grid dimension;
  * channels are blocked in multiples of 128 lanes so the elementwise
    recurrence maps onto full 8x128 VREGs; the time loop is a
    ``fori_loop`` over the chunk inside VMEM — sequential in time (the
    recurrence is inherently serial) but fully vectorised over channels;
  * no warp shuffles are needed: the (d, n) state outer product is an
    elementwise broadcast on the VPU.

Grid: (batch, d_blocks, seq_chunks), chunks innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)               # [bd, N]
    d = d_ref[...].astype(jnp.float32)               # [bd]

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)       # [bd]
        dt = dt_ref[0, t, :].astype(jnp.float32)      # [bd]
        bt = b_ref[0, t, :].astype(jnp.float32)       # [N]
        ct = c_ref[0, t, :].astype(jnp.float32)       # [N]
        da = jnp.exp(dt[:, None] * a)                 # [bd, N]
        h = da * h + (dt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + d * xt
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _finalize():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan(x, delta, a, b, c, d, h0=None, *, block_d: int = 256,
                   chunk: int = 128, interpret: bool = False):
    """x/delta: [B,S,D]; a: [D,N]; b/c: [B,S,N]; d: [D]; h0: [B,D,N].

    Returns (y [B,S,D], h_final [B,D,N])."""
    bb, s, dd = x.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bb, dd, n), jnp.float32)
    block_d = min(block_d, dd)
    chunk = min(chunk, s)
    if s % chunk != 0:
        raise ValueError(f"seq {s} must be divisible by chunk {chunk} "
                         "(pad inputs; OOB padding would poison the state)")
    if dd % block_d != 0:
        raise ValueError(f"d {dd} must be divisible by block_d {block_d}")
    nd = pl.cdiv(dd, block_d)
    nc = pl.cdiv(s, chunk)
    grid = (bb, nd, nc)

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((block_d, n), lambda bi, di, ci: (di, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((block_d,), lambda bi, di, ci: (di,)),
            pl.BlockSpec((1, block_d, n), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, block_d, n), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((bb, dd, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, delta, a, b, c, d, h0)
    return y, h_final
