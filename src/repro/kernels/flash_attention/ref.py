"""Pure-jnp oracle for the flash attention kernel (prefill path)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

_NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None):
    """q: [B,S,H,hd]; k/v: [B,T,KV,hd] with H % KV == 0.  Returns [B,S,H,hd].

    Query position i is aligned so that the last query attends to the last
    key: pos_q[i] = i + (T - S).
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    pos_q = jnp.arange(s) + (t - s)
    pos_k = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None, :]
    if window:
        mask &= pos_k[None, :] > pos_q[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = jnp.where(mask[None, None, None], p, 0.0)
    p = p / (jnp.sum(p, axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)
