"""Pallas TPU flash attention (prefill), online-softmax with blockwise tiling.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * tiles are sized for VMEM (not shared memory): block_q x hd and
    block_k x hd tiles with hd padded to a multiple of 128 keep the MXU
    matmul dims hardware-aligned (128x128 systolic array);
  * the softmax running stats (m, l) and the accumulator live in VMEM
    scratch that persists across the innermost (kv-block) grid dimension —
    the Pallas analogue of the register-resident accumulator on GPU;
  * GQA is expressed in the BlockSpec index maps (the kv head for query
    head h is h // (H // KV)), so no repeated K/V materialisation in HBM.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks), kv innermost.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int,
                 softcap: Optional[float], block_q: int, block_k: int,
                 seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # [bk, hd]
    # zero padded K/V rows of a partial last block: OOB reads pad with NaN
    # in interpret mode, and 0 * NaN would poison the accumulator
    kv_rows = ki * block_k + \
        jax.lax.broadcasted_iota(jnp.int32, (k.shape[0], 1), 0)
    kv_valid = kv_rows < seq_k
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    # positions: queries aligned to the end of the key sequence
    pos_q = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + (seq_k - seq_q)
    pos_k = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = pos_k < seq_k
    if causal:
        mask &= pos_q >= pos_k
    if window:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_ref[...] /
                             (l_ref[...][:, None] + 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B,S,H,hd]; k/v: [B,T,KV,hd].  Returns [B,S,H,hd]."""
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(t, block_k)
    grid = (b, h, nq, nk)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, seq_q=s, seq_k=t)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            # accumulator + online-softmax stats, persisted across kv blocks
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
