"""Jit'd public wrapper for the flash attention kernel.

On CPU (this container) ``interpret=True`` executes the kernel body in
Python for correctness validation; on TPU the same call compiles to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    return _kernel(q, k, v, causal=causal, window=window, softcap=softcap,
                   scale=scale, block_q=block_q, block_k=block_k,
                   interpret=interpret or not _on_tpu())


__all__ = ["flash_attention", "flash_attention_ref"]
