"""Pure-jnp/numpy oracle for the RWKV-6 WKV recurrence."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """Sequential reference of the RWKV-6 time-mix recurrence.

    r/k/v/w  [B, S, H, hd]   (w in (0,1): per-step decay)
    u        [H, hd]          bonus for the current token
    s0       [B, H, hd, hd]   initial state (optional)
    Returns (y [B,S,H,hd], s_final [B,H,hd,hd]).
    """
    b, s, h, hd = r.shape
    r = np.asarray(r, np.float32)
    k = np.asarray(k, np.float32)
    v = np.asarray(v, np.float32)
    w = np.asarray(w, np.float32)
    u = np.asarray(u, np.float32)
    st = np.zeros((b, h, hd, hd), np.float32) if s0 is None \
        else np.asarray(s0, np.float32).copy()
    ys = np.zeros((b, s, h, hd), np.float32)
    for t in range(s):
        kv = k[:, t, :, :, None] * v[:, t, :, None, :]        # [B,H,hd,hd]
        ys[:, t] = np.einsum("bhk,bhkv->bhv", r[:, t],
                             st + u[None, :, :, None] * kv)
        st = w[:, t, :, :, None] * st + kv
    return jnp.asarray(ys), jnp.asarray(st)
