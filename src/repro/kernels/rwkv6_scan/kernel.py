"""Pallas TPU RWKV-6 WKV scan, chunked along the sequence.

TPU adaptation notes (vs the reference CUDA wkv6 kernel):
  * the CUDA kernel assigns one thread per (head, channel) and keeps a
    column of the state in registers; on TPU the whole per-head state
    matrix [hd, hd] (64x64 = one 8x128-lane tile pair) sits in VMEM
    scratch, persisted across sequence chunks;
  * the rank-1 update k_t^T v_t and the readout r_t . S are expressed as
    broadcasts + reductions on the VPU — no MXU needed, so the kernel is
    bandwidth-bound exactly as on GPU, and chunking amortises HBM->VMEM
    transfers of r/k/v/w.

Grid: (batch, heads, seq_chunks), chunks innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                  # [hd]

    def step(t, s):
        rt = r_ref[0, t, 0, :].astype(jnp.float32)    # [hd]
        kt = k_ref[0, t, 0, :].astype(jnp.float32)
        vt = v_ref[0, t, 0, :].astype(jnp.float32)
        wt = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                # [hd, hd]
        y = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        return wt[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, step, s_ref[...])
    s_ref[...] = s

    @pl.when(ci == nc - 1)
    def _finalize():
        sout_ref[0, 0] = s.astype(sout_ref.dtype)


def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk: int = 128,
               interpret: bool = False):
    """r/k/v/w: [B,S,H,hd]; u: [H,hd]; s0: [B,H,hd,hd].

    Returns (y [B,S,H,hd], s_final [B,H,hd,hd])."""
    b, s, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    chunk = min(chunk, s)
    if s % chunk != 0:
        raise ValueError(f"seq {s} must be divisible by chunk {chunk} "
                         "(pad inputs; OOB padding would poison the state)")
    nc = pl.cdiv(s, chunk)
    grid = (b, h, nc)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    seq_spec = pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0))
    y, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hd), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_final
