"""Jit'd public wrapper for the RWKV-6 WKV scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan as _kernel
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk: int = 128,
               interpret: bool = False):
    return _kernel(r, k, v, w, u, s0, chunk=chunk,
                   interpret=interpret or not _on_tpu())


__all__ = ["rwkv6_scan", "rwkv6_scan_ref"]
