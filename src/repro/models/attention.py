"""Attention family: GQA (with RoPE / sliding-window / logit softcap) and
MLA (DeepSeek-V2 multi-head latent attention), plus enc-dec cross-attention.

Decode uses a fixed-capacity cache passed in and out of ``serve_step``:
  * full attention  — capacity = max seq_len, write slot = position
  * sliding window  — capacity = window, ring buffer, write slot = pos % W
  * MLA             — compressed (c_kv, k_rope) cache, absorbed-matmul decode
Every cache carries a per-slot ``pos`` array (int32, -1 = empty) used for
masking — this keeps ring buffers and continuous batching exact.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig
from repro.models.common import ShardPolicy, apply_rope, rms_norm, shard, softcap
from repro.models.params import P

_NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Parameter plans
# ---------------------------------------------------------------------------

def attention_plan(cfg: ModelConfig, layer: LayerSpec) -> dict:
    a = cfg.attn
    d = cfg.d_model
    if a.kind == "mla":
        qk_in = a.nope_head_dim + a.rope_head_dim
        plan = {
            "wq_a": P((d, a.q_lora_rank), pspec=("data", None)),
            "q_norm": P((a.q_lora_rank,), dtype="float32", init="zeros", pspec=()),
            "wq_b": P((a.q_lora_rank, a.num_heads, qk_in), fan_in=a.q_lora_rank,
                      pspec=(None, "model", None)),
            "wkv_a": P((d, a.kv_lora_rank + a.rope_head_dim), pspec=("data", None)),
            "kv_norm": P((a.kv_lora_rank,), dtype="float32", init="zeros", pspec=()),
            "wk_b": P((a.kv_lora_rank, a.num_heads, a.nope_head_dim),
                      fan_in=a.kv_lora_rank, pspec=(None, "model", None)),
            "wv_b": P((a.kv_lora_rank, a.num_heads, a.v_head_dim),
                      fan_in=a.kv_lora_rank, pspec=(None, "model", None)),
            "wo": P((a.num_heads, a.v_head_dim, d),
                    fan_in=a.num_heads * a.v_head_dim,
                    pspec=("model", None, "data")),
        }
        return plan
    plan = {
        "wq": P((d, a.num_heads, a.head_dim), pspec=("data", "model", None)),
        "wk": P((d, a.num_kv_heads, a.head_dim), pspec=("data", "model", None),
                alt=("data", None, None)),
        "wv": P((d, a.num_kv_heads, a.head_dim), pspec=("data", "model", None),
                alt=("data", None, None)),
        "wo": P((a.num_heads, a.head_dim, d), fan_in=a.num_heads * a.head_dim,
                pspec=("model", None, "data")),
    }
    return plan


def cross_attention_plan(cfg: ModelConfig) -> dict:
    a = cfg.attn
    d = cfg.d_model
    return {
        "wq": P((d, a.num_heads, a.head_dim), pspec=("data", "model", None)),
        "wk": P((d, a.num_kv_heads, a.head_dim), pspec=("data", "model", None),
                alt=("data", None, None)),
        "wv": P((d, a.num_kv_heads, a.head_dim), pspec=("data", "model", None),
                alt=("data", None, None)),
        "wo": P((a.num_heads, a.head_dim, d), fan_in=a.num_heads * a.head_dim,
                pspec=("model", None, "data")),
    }


def kv_quantized() -> bool:
    """Opt-in int8 KV cache (beyond-paper; halves the decode memory term).
    Per-(token, kv-head) symmetric scales; MLA caches are already
    rank-compressed and stay bf16."""
    import os
    return os.environ.get("REPRO_KV_INT8", "0") == "1"


def attn_cache_plan(cfg: ModelConfig, layer: LayerSpec, batch: int, seq_len: int,
                    policy: ShardPolicy) -> dict:
    """Decode-cache plan for one attention layer."""
    a = cfg.attn
    cap = min(seq_len, layer.window) if layer.window else seq_len
    kvp = policy.kv_cache or ()
    pos_spec = tuple(kvp[:2])
    if a.kind == "mla":
        mp = policy.mla_cache or ()
        return {
            "ckv": P((batch, cap, a.kv_lora_rank), pspec=mp),
            "krope": P((batch, cap, a.rope_head_dim), pspec=tuple(mp[:2])),
            "pos": P((batch, cap), dtype="int32", pspec=tuple(mp[:2])),
        }
    if kv_quantized():
        scale_spec = tuple(kvp[:3])
        return {
            "k": P((batch, cap, a.num_kv_heads, a.head_dim), dtype="int8",
                   pspec=kvp),
            "v": P((batch, cap, a.num_kv_heads, a.head_dim), dtype="int8",
                   pspec=kvp),
            "k_scale": P((batch, cap, a.num_kv_heads), dtype="bfloat16",
                         pspec=scale_spec),
            "v_scale": P((batch, cap, a.num_kv_heads), dtype="bfloat16",
                         pspec=scale_spec),
            "pos": P((batch, cap), dtype="int32", pspec=pos_spec),
        }
    # the unquantized cache stores the model dtype (bf16 for every real
    # config; fp32 configs keep fp32 so cached K/V match prefill exactly)
    return {
        "k": P((batch, cap, a.num_kv_heads, a.head_dim), dtype=cfg.dtype,
               pspec=kvp),
        "v": P((batch, cap, a.num_kv_heads, a.head_dim), dtype=cfg.dtype,
               pspec=kvp),
        "pos": P((batch, cap), dtype="int32", pspec=pos_spec),
    }


def _quantize_kv(x):
    """x: [..., hd] -> (int8 values, per-row scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def cross_cache_plan(cfg: ModelConfig, batch: int, enc_len: int,
                     policy: ShardPolicy) -> dict:
    a = cfg.attn
    kvp = policy.kv_cache or ()
    return {
        "ck": P((batch, enc_len, a.num_kv_heads, a.head_dim), dtype=cfg.dtype,
                pspec=kvp),
        "cv": P((batch, enc_len, a.num_kv_heads, a.head_dim), dtype=cfg.dtype,
                pspec=kvp),
    }


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _split_heads(q, num_kv):
    """[B, S, H, hd] -> [B, S, KV, G, hd]."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def _gqa_scores(q, k, scale, cap):
    """q: [B,S,KV,G,hd], k: [B,T,KV,hd] -> [B,KV,G,S,T] float32."""
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    return softcap(scores, cap)


def _masked_softmax(scores, mask):
    scores = jnp.where(mask, scores, _NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - jax.lax.stop_gradient(m))
    p = jnp.where(mask, p, 0.0)
    return p / (jnp.sum(p, axis=-1, keepdims=True) + 1e-30)


_CHUNK_THRESHOLD = 1 << 21   # S*T above which the q dimension is chunked


def _q_chunk() -> int:
    """Query-chunk size for the blockwise jnp attention path; overridable
    for §Perf experiments (the Pallas kernel's block_q analogue)."""
    import os
    return int(os.environ.get("REPRO_Q_CHUNK", "512"))


def _attend_block(qg, k, v, pos_q, pos_k, scale, a, layer, causal, dtype):
    """qg: [B,bq,KV,G,hd]; k/v: [B,T,KV,hd]; pos_q: [B,bq]; pos_k: [B,T]."""
    scores = _gqa_scores(qg, k, scale, a.logit_softcap)   # [B,KV,G,bq,T]
    ps = pos_q[:, None, None, :, None]
    pt = pos_k[:, None, None, None, :]
    mask = (pt <= ps) if causal else jnp.broadcast_to(
        jnp.bool_(True), scores.shape)
    if layer.window:
        mask = mask & (pt > ps - layer.window)
    p = _masked_softmax(scores, mask)
    return jnp.einsum("bkgst,btkh->bskgh", p.astype(dtype), v)


def gqa_prefill(params, x, positions, layer: LayerSpec, cfg: ModelConfig,
                policy: ShardPolicy, *, causal: bool = True):
    """x: [B,S,d]; positions: [B,S] int32.  Returns (out, cache|None).

    When S*T exceeds a threshold the query dimension is processed in
    chunks under lax.scan with an inner checkpoint — the pure-jnp analogue
    of the flash kernel's blockwise tiling, bounding live memory at one
    [B,KV,G,chunk,T] score block instead of the full quadratic tensor.
    """
    a = cfg.attn
    b, s, _ = x.shape
    scale = 1.0 / jnp.sqrt(a.head_dim).astype(jnp.float32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = shard(apply_rope(q, positions, cfg.rope_theta), policy.heads)
    k = apply_rope(k, positions, cfg.rope_theta)
    qg = _split_heads(q, a.num_kv_heads)                  # [B,S,KV,G,hd]

    qc = _q_chunk()
    if s * s <= _CHUNK_THRESHOLD or s % qc != 0:
        ctx = _attend_block(qg, k, v, positions, positions, scale, a, layer,
                            causal, x.dtype)
    else:
        nc = s // qc
        q_cs = jnp.moveaxis(
            qg.reshape(b, nc, qc, a.num_kv_heads, qg.shape[3], -1),
            1, 0)                                          # [nc,B,qc,KV,G,hd]
        pos_cs = jnp.moveaxis(positions.reshape(b, nc, qc), 1, 0)
        starts = jnp.arange(nc, dtype=jnp.int32) * qc
        # window clipping: a sliding-window layer's q chunk only sees keys
        # in [chunk_start - window, chunk_end) — skip the rest entirely
        # (~T/(window+qc) less attention compute + K/V traffic)
        clip = bool(layer.window) and (layer.window + qc) < s
        span = min(layer.window + qc, s) if clip else s

        @jax.checkpoint
        def body(carry, inp):
            q_blk, pos_blk, start = inp
            if clip:
                lo = jnp.clip(start - layer.window, 0, s - span)
                k_blk = jax.lax.dynamic_slice_in_dim(k, lo, span, axis=1)
                v_blk = jax.lax.dynamic_slice_in_dim(v, lo, span, axis=1)
                pos_k = jax.lax.dynamic_slice_in_dim(positions, lo, span,
                                                     axis=1)
            else:
                k_blk, v_blk, pos_k = k, v, positions
            out_blk = _attend_block(q_blk, k_blk, v_blk, pos_blk, pos_k,
                                    scale, a, layer, causal, x.dtype)
            return carry, out_blk

        _, ctx_cs = jax.lax.scan(body, (), (q_cs, pos_cs, starts))
        ctx = jnp.moveaxis(ctx_cs, 0, 1).reshape(
            b, s, a.num_kv_heads, qg.shape[3], a.head_dim)
    ctx = ctx.reshape(b, s, a.num_heads, a.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])
    return shard(out, policy.act), (k, v)


def build_gqa_cache(k, v, positions, layer: LayerSpec, seq_cap: int,
                    policy: ShardPolicy):
    """Turn prefill K/V into a decode cache (ring-buffered if windowed)."""
    b, s = positions.shape
    cap = min(seq_cap, layer.window) if layer.window else seq_cap
    kvh, hd = k.shape[2], k.shape[3]
    quant = kv_quantized()
    store_dt = jnp.int8 if quant else k.dtype
    ck = jnp.zeros((b, cap, kvh, hd), store_dt)
    cv = jnp.zeros((b, cap, kvh, hd), store_dt)
    cpos = jnp.full((b, cap), -1, jnp.int32)
    take = min(s, cap)
    k_t, v_t, p_t = k[:, -take:], v[:, -take:], positions[:, -take:]
    slots = p_t % cap                                     # [B, take]
    bidx = jnp.arange(b)[:, None]
    out = {"pos": cpos.at[bidx, slots].set(p_t)}
    if quant:
        kq, ks = _quantize_kv(k_t)
        vq, vs = _quantize_kv(v_t)
        out["k"] = shard(ck.at[bidx, slots].set(kq), policy.kv_cache)
        out["v"] = shard(cv.at[bidx, slots].set(vq), policy.kv_cache)
        zs = jnp.zeros((b, cap, kvh), jnp.bfloat16)
        out["k_scale"] = zs.at[bidx, slots].set(ks)
        out["v_scale"] = zs.at[bidx, slots].set(vs)
    else:
        out["k"] = shard(ck.at[bidx, slots].set(k_t), policy.kv_cache)
        out["v"] = shard(cv.at[bidx, slots].set(v_t), policy.kv_cache)
    return out


def gqa_decode(params, x, cache, positions, layer: LayerSpec, cfg: ModelConfig,
               policy: ShardPolicy):
    """x: [B,1,d]; positions: [B] int32.  Returns (out, new_cache)."""
    a = cfg.attn
    b = x.shape[0]
    scale = 1.0 / jnp.sqrt(a.head_dim).astype(jnp.float32)
    pos2 = positions[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)
    cap = cache["k"].shape[1]
    slots = positions % cap if layer.window else positions
    bidx = jnp.arange(b)
    quant = "k_scale" in cache
    new_cache = {}
    if quant:
        kq, ks = _quantize_kv(k[:, 0])
        vq, vs = _quantize_kv(v[:, 0])
        ck = shard(cache["k"].at[bidx, slots].set(kq), policy.kv_cache)
        cv = shard(cache["v"].at[bidx, slots].set(vq), policy.kv_cache)
        k_sc = cache["k_scale"].at[bidx, slots].set(ks)
        v_sc = cache["v_scale"].at[bidx, slots].set(vs)
        k_read = _dequantize_kv(ck, k_sc, x.dtype)
        v_read = _dequantize_kv(cv, v_sc, x.dtype)
        new_cache.update({"k_scale": k_sc, "v_scale": v_sc})
    else:
        ck = shard(cache["k"].at[bidx, slots].set(k[:, 0]), policy.kv_cache)
        cv = shard(cache["v"].at[bidx, slots].set(v[:, 0]), policy.kv_cache)
        k_read, v_read = ck, cv
    cpos = cache["pos"].at[bidx, slots].set(positions)
    new_cache.update({"k": ck, "v": cv, "pos": cpos})
    qg = _split_heads(q, a.num_kv_heads)                  # [B,1,KV,G,hd]
    scores = _gqa_scores(qg, k_read, scale, a.logit_softcap)  # [B,KV,G,1,T]
    pt = cpos[:, None, None, None, :]
    ps = positions[:, None, None, None, None]
    mask = (pt >= 0) & (pt <= ps)
    if layer.window:
        mask = mask & (pt > ps - layer.window)
    p = _masked_softmax(scores, mask)
    ctx = jnp.einsum("bkgst,btkh->bskgh", p.astype(x.dtype), v_read)
    ctx = ctx.reshape(b, 1, a.num_heads, a.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])
    return shard(out, policy.act), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_qkv_prefill(params, x, positions, cfg):
    a = cfg.attn
    q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"]),
                     params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"])
    q_nope, q_rope = (q[..., : a.nope_head_dim], q[..., a.nope_head_dim:])
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    ckv = rms_norm(kv[..., : a.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, a.kv_lora_rank:], positions,
                        cfg.rope_theta)[..., 0, :]        # [B,S,rope]
    return q_nope, q_rope, ckv, k_rope


def _mla_attend_block(q_nope, q_rope, k_nope, k_rope, v, pos_q, pos_k,
                      scale, dtype):
    """q_*: [B,bq,H,*]; k/v: [B,T,H,*]; returns ctx [B,bq,H,v]."""
    s_nope = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    mask = pos_q[:, None, :, None] >= pos_k[:, None, None, :]
    p = _masked_softmax(scores, mask)
    return jnp.einsum("bhst,bthv->bshv", p.astype(dtype), v)


def mla_prefill(params, x, positions, layer: LayerSpec, cfg: ModelConfig,
                policy: ShardPolicy):
    """Chunked like gqa_prefill: the [B,H,chunk,T] score block replaces the
    full quadratic tensor (decompressed K/V are still materialised once —
    the prefill-side asymptotics favour decompression; decode uses the
    absorbed form)."""
    a = cfg.attn
    b, s, _ = x.shape
    scale = 1.0 / jnp.sqrt(float(a.nope_head_dim + a.rope_head_dim))
    q_nope, q_rope, ckv, k_rope = _mla_qkv_prefill(params, x, positions, cfg)
    k_nope = jnp.einsum("btr,rhk->bthk", ckv, params["wk_b"])
    v = jnp.einsum("btr,rhv->bthv", ckv, params["wv_b"])

    qc = _q_chunk()
    if s * s <= _CHUNK_THRESHOLD or s % qc != 0:
        ctx = _mla_attend_block(q_nope, q_rope, k_nope, k_rope, v,
                                positions, positions, scale, x.dtype)
    else:
        nc = s // qc

        def resplit(t):
            return jnp.moveaxis(
                t.reshape((b, nc, qc) + t.shape[2:]), 1, 0)

        @jax.checkpoint
        def body(carry, inp):
            qn_blk, qr_blk, pos_blk = inp
            out_blk = _mla_attend_block(qn_blk, qr_blk, k_nope, k_rope, v,
                                        pos_blk, positions, scale, x.dtype)
            return carry, out_blk

        _, ctx_cs = jax.lax.scan(
            body, (), (resplit(q_nope), resplit(q_rope), resplit(positions)))
        ctx = jnp.moveaxis(ctx_cs, 0, 1).reshape(
            b, s, a.num_heads, a.v_head_dim)
    out = jnp.einsum("bshv,hvd->bsd", ctx, params["wo"])
    return shard(out, policy.act), (ckv, k_rope)


def build_mla_cache(ckv, k_rope, positions, seq_cap: int, policy: ShardPolicy):
    b, s = positions.shape
    out_ckv = jnp.zeros((b, seq_cap) + ckv.shape[2:], ckv.dtype)
    out_kr = jnp.zeros((b, seq_cap) + k_rope.shape[2:], k_rope.dtype)
    cpos = jnp.full((b, seq_cap), -1, jnp.int32)
    take = min(s, seq_cap)
    bidx = jnp.arange(b)[:, None]
    slots = positions[:, -take:]
    out_ckv = out_ckv.at[bidx, slots].set(ckv[:, -take:])
    out_kr = out_kr.at[bidx, slots].set(k_rope[:, -take:])
    cpos = cpos.at[bidx, slots].set(positions[:, -take:])
    return {"ckv": shard(out_ckv, policy.mla_cache), "krope": out_kr, "pos": cpos}


def mla_decode(params, x, cache, positions, layer: LayerSpec, cfg: ModelConfig,
               policy: ShardPolicy):
    """Absorbed-matmul MLA decode: never materialises per-head K/V."""
    a = cfg.attn
    b = x.shape[0]
    scale = 1.0 / jnp.sqrt(float(a.nope_head_dim + a.rope_head_dim))
    pos2 = positions[:, None]
    q_nope, q_rope, ckv_new, kr_new = _mla_qkv_prefill(params, x, pos2, cfg)
    bidx = jnp.arange(b)
    ckv = shard(cache["ckv"].at[bidx, positions].set(ckv_new[:, 0]),
                policy.mla_cache)
    krope = cache["krope"].at[bidx, positions].set(kr_new[:, 0])
    cpos = cache["pos"].at[bidx, positions].set(positions)
    # absorb W_k_b into the query:  q_abs[b,h,r] = sum_k q_nope[b,h,k] wk_b[r,h,k]
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])
    s_nope = jnp.einsum("bshr,btr->bhst", q_abs, ckv,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, krope,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale
    mask = (cpos[:, None, None, :] >= 0) & \
           (cpos[:, None, None, :] <= positions[:, None, None, None])
    p = _masked_softmax(scores, mask)
    ctx_c = jnp.einsum("bhst,btr->bshr", p.astype(x.dtype), ckv)  # compressed ctx
    ctx = jnp.einsum("bshr,rhv->bshv", ctx_c, params["wv_b"])
    out = jnp.einsum("bshv,hvd->bsd", ctx, params["wo"])
    return shard(out, policy.act), {"ckv": ckv, "krope": krope, "pos": cpos}


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder layers)
# ---------------------------------------------------------------------------

def cross_attn_kv(params, memory):
    """memory: [B, F, d] encoder output -> (ck, cv) [B, F, KV, hd]."""
    ck = jnp.einsum("bfd,dhk->bfhk", memory, params["wk"])
    cv = jnp.einsum("bfd,dhk->bfhk", memory, params["wv"])
    return ck, cv


def cross_attn(params, x, ck, cv, cfg: ModelConfig, policy: ShardPolicy):
    """x: [B,S,d]; attends (non-causal) over encoder memory K/V."""
    a = cfg.attn
    scale = 1.0 / jnp.sqrt(a.head_dim).astype(jnp.float32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    qg = _split_heads(q, a.num_kv_heads)
    scores = _gqa_scores(qg, ck, scale, None)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgst,btkh->bskgh", p.astype(x.dtype), cv)
    ctx = ctx.reshape(x.shape[0], x.shape[1], a.num_heads, a.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])
    return shard(out, policy.act)
