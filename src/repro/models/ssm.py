"""Mamba-1 selective state-space mixer (Jamba's SSM layers).

Prefill/train uses an associative scan over the sequence (log-depth HLO);
decode is a single recurrent state update.  State per layer:
  conv_state [B, d_inner, d_conv-1]  (depthwise conv tail)
  ssm_state  [B, d_inner, d_state]   (float32)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MambaSpec, ModelConfig
from repro.models.common import ShardPolicy, shard
from repro.models.params import P


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank


def mamba_plan(cfg: ModelConfig) -> dict:
    m = cfg.mamba
    d = cfg.d_model
    d_inner, dt_rank = _dims(cfg)
    return {
        "in_proj": P((d, 2, d_inner), pspec=("data", None, "model")),
        "conv_w": P((d_inner, m.d_conv), init="small", pspec=("model", None)),
        "conv_b": P((d_inner,), init="zeros", pspec=("model",)),
        "x_proj": P((d_inner, dt_rank + 2 * m.d_state), pspec=("model", None)),
        "dt_proj": P((dt_rank, d_inner), fan_in=dt_rank, pspec=(None, "model")),
        "dt_bias": P((d_inner,), dtype="float32", init="small", pspec=("model",)),
        "A_log": P((d_inner, m.d_state), dtype="float32",
                   init="identity_decay", pspec=("model", None)),
        "D": P((d_inner,), dtype="float32", init="ones", pspec=("model",)),
        "out_proj": P((d_inner, d), fan_in=d_inner, pspec=("model", "data")),
    }


def mamba_state_plan(cfg: ModelConfig, batch: int, policy: ShardPolicy) -> dict:
    m = cfg.mamba
    d_inner, _ = _dims(cfg)
    sp = policy.state or ()
    return {
        "conv": P((batch, d_inner, m.d_conv - 1), pspec=sp),
        "ssm": P((batch, d_inner, m.d_state), dtype="float32", pspec=sp),
    }


def _ssm_coeffs(params, xc, cfg: ModelConfig):
    """xc: [B, S, d_inner] post-conv activations -> (dA, dBx, C) coefficients."""
    m = cfg.mamba
    _, dt_rank = _dims(cfg)
    proj = jnp.einsum("bsd,dr->bsr", xc, params["x_proj"])
    dt = proj[..., :dt_rank]
    bmat = proj[..., dt_rank: dt_rank + m.d_state].astype(jnp.float32)
    cmat = proj[..., dt_rank + m.d_state:].astype(jnp.float32)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                                    # [B,S,d_inner]
    a = -jnp.exp(params["A_log"])                               # [d_inner, n]
    dA = jnp.exp(delta[..., None] * a)                          # [B,S,d,n]
    dBx = (delta[..., None] * bmat[..., None, :]
           * xc.astype(jnp.float32)[..., None])                 # [B,S,d,n]
    return dA, dBx, cmat


def mamba_prefill(params, x, cfg: ModelConfig, policy: ShardPolicy,
                  conv_init=None, ssm_init=None):
    """x: [B,S,d].  Returns (out [B,S,d], state dict for decode)."""
    m = cfg.mamba
    xz = jnp.einsum("bsd,dci->bsci", x, params["in_proj"])
    xin, z = xz[..., 0, :], xz[..., 1, :]                       # [B,S,d_inner]
    if policy.act:
        xin = shard(xin, (policy.act[0], None, "model"))
    # depthwise causal conv along S
    pad = m.d_conv - 1
    if conv_init is not None:
        tail = jnp.swapaxes(conv_init, 1, 2)                    # [B,pad,d_inner]
    else:
        tail = jnp.zeros((xin.shape[0], pad, xin.shape[2]), xin.dtype)
    xpad = jnp.concatenate([tail, xin], axis=1)                 # [B,S+pad,d_in]
    stacked = jnp.stack(
        [xpad[:, i: i + xin.shape[1]] for i in range(m.d_conv)], axis=-1)
    xc = jax.nn.silu(jnp.einsum("bsdc,dc->bsd", stacked, params["conv_w"])
                     + params["conv_b"])
    dA, dBx, cmat = _ssm_coeffs(params, xc, cfg)
    h0 = ssm_init if ssm_init is not None else \
        jnp.zeros((x.shape[0], dA.shape[2], m.d_state), jnp.float32)

    # associative scan over S:  h_t = dA_t * h_{t-1} + dBx_t
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    dA_s = jnp.moveaxis(dA, 1, 0)                               # [S,B,d,n]
    dBx_s = jnp.moveaxis(dBx, 1, 0)
    # fold initial state into the first element
    dBx_s = dBx_s.at[0].add(dA_s[0] * h0)
    aa, hh = jax.lax.associative_scan(combine, (dA_s, dBx_s), axis=0)
    h = jnp.moveaxis(hh, 0, 1)                                  # [B,S,d,n]
    y = jnp.einsum("bsdn,bsn->bsd", h, cmat)
    y = (y + params["D"] * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,do->bso", y, params["out_proj"])
    # conv tail: last (d_conv-1) inputs, shape [B, d_inner, d_conv-1]
    state = {"conv": jnp.swapaxes(xpad[:, -pad:], 1, 2), "ssm": h[:, -1]}
    return shard(out, policy.act), state


def mamba_decode(params, x, state, cfg: ModelConfig, policy: ShardPolicy):
    """x: [B,1,d]; state: {conv [B,d_inner,pad], ssm [B,d_inner,n]}."""
    m = cfg.mamba
    xz = jnp.einsum("bsd,dci->bsci", x, params["in_proj"])
    xin, z = xz[:, 0, 0, :], xz[:, 0, 1, :]                     # [B,d_inner]
    window = jnp.concatenate([state["conv"], xin[..., None]], axis=-1)
    xc = jax.nn.silu(jnp.einsum("bdc,dc->bd", window, params["conv_w"])
                     + params["conv_b"])
    dA, dBx, cmat = _ssm_coeffs(params, xc[:, None], cfg)
    h = dA[:, 0] * state["ssm"] + dBx[:, 0]                     # [B,d,n]
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])
    y = (y + params["D"] * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bd,do->bo", y, params["out_proj"])[:, None]
    new_state = {"conv": shard(window[..., 1:], policy.state),
                 "ssm": shard(h, policy.state)}
    return shard(out, policy.act), new_state
