"""Unified model assembly for all assigned architectures.

A model is a layer *pattern* (prefix + period x repeats, see configs.base).
The periodic part runs under ``jax.lax.scan`` over parameters stacked on a
leading ``repeats`` axis, so HLO size and compile time are depth-independent.

Three entry points (all pure functions of (params, inputs)):
  * ``train_loss``   — full-sequence forward + causal-LM cross-entropy
  * ``prefill``      — full-sequence forward, returns last-token logits and a
                       decode cache (ring-buffered for windowed layers)
  * ``decode_step``  — one token against the cache (``serve_step`` in launch)

Enc-dec models (seamless) additionally run ``encode`` over (stubbed) frame
embeddings; decoder layers cross-attend to the encoder memory.
VLM models prepend projected (stubbed) patch embeddings to the token stream.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (NO_POLICY, ShardPolicy, cross_entropy_loss,
                                 gated_ffn, rms_norm, shard, softcap)
from repro.models.params import (P, init_from_plan, shardings_from_plan,
                                 specs_from_plan)


# ---------------------------------------------------------------------------
# Parameter plans
# ---------------------------------------------------------------------------

def dense_ffn_plan(cfg: ModelConfig, spec) -> dict:
    d = cfg.d_model
    return {
        "w_in": P((d, 2, spec.d_ff), pspec=("data", None, "model")),
        "w_out": P((spec.d_ff, d), fan_in=spec.d_ff, pspec=("model", "data")),
    }


def layer_plan(cfg: ModelConfig, layer: LayerSpec) -> dict:
    d = cfg.d_model
    plan: Dict[str, Any] = {"norm1": P((d,), dtype="float32", init="zeros",
                                       pspec=())}
    if layer.mixer == "attn":
        plan["attn"] = attn_mod.attention_plan(cfg, layer)
    elif layer.mixer == "mamba":
        plan["mamba"] = ssm_mod.mamba_plan(cfg)
    elif layer.mixer == "rwkv6":
        plan["rwkv"] = rwkv_mod.rwkv_plan(cfg)
    else:
        raise ValueError(layer.mixer)
    if layer.cross_attn:
        plan["norm_x"] = P((d,), dtype="float32", init="zeros", pspec=())
        plan["cross"] = attn_mod.cross_attention_plan(cfg)
    if layer.ffn in ("dense", "moe"):
        plan["norm2"] = P((d,), dtype="float32", init="zeros", pspec=())
        fspec = cfg.ffn_spec_for(layer)
        if layer.ffn == "moe":
            plan["moe"] = moe_mod.moe_plan(cfg, fspec)
        else:
            plan["ffn"] = dense_ffn_plan(cfg, fspec)
    # rwkv channel-mix params live inside the rwkv plan ("ffn" == "rwkv_cm")
    return plan


def _stack_leaf(p: P, n: int) -> P:
    return P((n,) + tuple(p.shape), dtype=p.dtype, init=p.init, fan_in=p.fan_in,
             pspec=(None,) + tuple(p.pspec),
             alt=(None,) + tuple(p.alt) if p.alt is not None else None)


def stack_plan(plan, n: int):
    return jax.tree.map(lambda p: _stack_leaf(p, n), plan,
                        is_leaf=lambda x: isinstance(x, P))


def model_plan(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    plan: Dict[str, Any] = {
        "embed": P((v, d), init="small", pspec=("model", "data")),
        "final_norm": P((d,), dtype="float32", init="zeros", pspec=()),
    }
    if not cfg.tie_embeddings:
        plan["lm_head"] = P((d, v), pspec=("data", "model"))
    if cfg.frontend.kind != "none":
        plan["frontend_proj"] = P((cfg.frontend.embed_dim, d),
                                  pspec=(None, "data"))
    if cfg.prefix:
        plan["prefix"] = {f"layer{i}": layer_plan(cfg, l)
                          for i, l in enumerate(cfg.prefix)}
    if cfg.period:
        period = {f"sub{i}": layer_plan(cfg, l)
                  for i, l in enumerate(cfg.period)}
        plan["period"] = stack_plan(period, cfg.repeats)
    if cfg.encoder:
        enc_layer = LayerSpec(mixer="attn", ffn="dense")
        enc = {"sub0": layer_plan(cfg, enc_layer)}
        plan["encoder"] = {
            "period": stack_plan(enc, cfg.encoder.num_layers),
            "final_norm": P((d,), dtype="float32", init="zeros", pspec=()),
        }
    return plan


def layer_cache_plan(cfg: ModelConfig, layer: LayerSpec, batch: int,
                     seq_cap: int, policy: ShardPolicy,
                     enc_len: int = 0) -> dict:
    plan: Dict[str, Any] = {}
    if layer.mixer == "attn":
        plan["self"] = attn_mod.attn_cache_plan(cfg, layer, batch, seq_cap, policy)
    elif layer.mixer == "mamba":
        plan["self"] = ssm_mod.mamba_state_plan(cfg, batch, policy)
    elif layer.mixer == "rwkv6":
        plan["self"] = rwkv_mod.rwkv_state_plan(cfg, batch, policy)
    if layer.cross_attn and enc_len:
        plan["cross"] = attn_mod.cross_cache_plan(cfg, batch, enc_len, policy)
    return plan


def cache_plan(cfg: ModelConfig, batch: int, seq_cap: int, policy: ShardPolicy,
               enc_len: int = 0) -> dict:
    plan: Dict[str, Any] = {}
    if cfg.prefix:
        plan["prefix"] = {
            f"layer{i}": layer_cache_plan(cfg, l, batch, seq_cap, policy, enc_len)
            for i, l in enumerate(cfg.prefix)}
    if cfg.period:
        period = {f"sub{i}": layer_cache_plan(cfg, l, batch, seq_cap, policy,
                                              enc_len)
                  for i, l in enumerate(cfg.period)}
        plan["period"] = stack_plan(period, cfg.repeats)
    return plan


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _apply_ffn(lp, h, layer: LayerSpec, cfg: ModelConfig, policy: ShardPolicy,
               cache_shift=None):
    """Returns (h, aux, new_cm_shift)."""
    aux = jnp.zeros((), jnp.float32)
    new_shift = None
    if layer.ffn == "dense":
        x = rms_norm(h, lp["norm2"], cfg.norm_eps)
        fspec = cfg.ffn_spec_for(layer)
        h = h + gated_ffn(x, lp["ffn"]["w_in"], lp["ffn"]["w_out"],
                          fspec.activation, policy)
    elif layer.ffn == "moe":
        x = rms_norm(h, lp["norm2"], cfg.norm_eps)
        out, aux = moe_mod.moe_ffn(lp["moe"], x, cfg.ffn_spec_for(layer), cfg,
                                   policy)
        h = h + out
    elif layer.ffn == "rwkv_cm":
        # channel-mix shares the rwkv param dict and token-shift state
        x = rms_norm(h, lp["norm2_cm"], cfg.norm_eps) if "norm2_cm" in lp else h
        prev = cache_shift if cache_shift is not None else \
            jnp.zeros((h.shape[0], h.shape[-1]), h.dtype)
        out, new_shift = rwkv_mod.rwkv_channel_mix(lp["rwkv"], x, prev, policy)
        h = h + out
    return h, aux, new_shift


def apply_layer_seq(lp, h, layer: LayerSpec, cfg: ModelConfig,
                    positions, policy: ShardPolicy, *, want_cache: bool,
                    seq_cap: int, memory=None, init_state=None):
    """Full-sequence (train/prefill) layer application.

    Returns (h, cache_out, aux).  ``cache_out`` matches layer_cache_plan when
    want_cache, else ().
    """
    aux = jnp.zeros((), jnp.float32)
    cache_out: Dict[str, Any] = {}
    xin = rms_norm(h, lp["norm1"], cfg.norm_eps)
    if layer.mixer == "attn":
        if cfg.attn.kind == "mla":
            out, (ckv, krope) = attn_mod.mla_prefill(lp["attn"], xin, positions,
                                                     layer, cfg, policy)
            if want_cache:
                cache_out["self"] = attn_mod.build_mla_cache(
                    ckv, krope, positions, seq_cap, policy)
        else:
            out, (k, v) = attn_mod.gqa_prefill(lp["attn"], xin, positions,
                                               layer, cfg, policy)
            if want_cache:
                cache_out["self"] = attn_mod.build_gqa_cache(
                    k, v, positions, layer, seq_cap, policy)
        h = h + out
    elif layer.mixer == "mamba":
        conv0 = ssm0 = None
        if init_state is not None:
            conv0, ssm0 = init_state["self"]["conv"], init_state["self"]["ssm"]
        out, state = ssm_mod.mamba_prefill(lp["mamba"], xin, cfg, policy,
                                           conv_init=conv0, ssm_init=ssm0)
        if want_cache:
            cache_out["self"] = state
        h = h + out
    elif layer.mixer == "rwkv6":
        b = h.shape[0]
        prev = init_state["self"]["shift_att"] if init_state is not None else \
            jnp.zeros((b, h.shape[-1]), h.dtype)
        wkv0 = init_state["self"]["wkv"] if init_state is not None else \
            jnp.zeros((b, cfg.d_model // cfg.rwkv.head_dim,
                       cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32)
        out, (new_shift, new_wkv) = rwkv_mod.rwkv_time_mix(
            lp["rwkv"], xin, prev, wkv0, cfg, policy)
        h = h + out
        # channel-mix (rwkv ffn) with its own shift state
        x2 = h
        prev_cm = init_state["self"]["shift_ffn"] if init_state is not None \
            else jnp.zeros((b, h.shape[-1]), h.dtype)
        cm_out, new_cm = rwkv_mod.rwkv_channel_mix(lp["rwkv"], x2, prev_cm,
                                                   policy)
        h = h + cm_out
        if want_cache:
            cache_out["self"] = {"shift_att": new_shift, "shift_ffn": new_cm,
                                 "wkv": new_wkv}
        return h, (cache_out if want_cache else ()), aux

    if layer.cross_attn and memory is not None:
        xq = rms_norm(h, lp["norm_x"], cfg.norm_eps)
        ck, cv = attn_mod.cross_attn_kv(lp["cross"], memory)
        h = h + attn_mod.cross_attn(lp["cross"], xq, ck, cv, cfg, policy)
        if want_cache:
            cache_out["cross"] = {"ck": shard(ck, policy.kv_cache),
                                  "cv": shard(cv, policy.kv_cache)}

    if layer.ffn in ("dense", "moe"):
        h, aux, _ = _apply_ffn(lp, h, layer, cfg, policy)
    return h, (cache_out if want_cache else ()), aux


def apply_layer_decode(lp, h, layer: LayerSpec, cfg: ModelConfig, positions,
                       cache, policy: ShardPolicy):
    """Single-token layer application.  h: [B,1,d]; positions: [B].

    Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache)
    xin = rms_norm(h, lp["norm1"], cfg.norm_eps)
    if layer.mixer == "attn":
        if cfg.attn.kind == "mla":
            out, cs = attn_mod.mla_decode(lp["attn"], xin, cache["self"],
                                          positions, layer, cfg, policy)
        else:
            out, cs = attn_mod.gqa_decode(lp["attn"], xin, cache["self"],
                                          positions, layer, cfg, policy)
        new_cache["self"] = cs
        h = h + out
    elif layer.mixer == "mamba":
        out, cs = ssm_mod.mamba_decode(lp["mamba"], xin, cache["self"], cfg,
                                       policy)
        new_cache["self"] = cs
        h = h + out
    elif layer.mixer == "rwkv6":
        st = cache["self"]
        out, (new_shift, new_wkv) = rwkv_mod.rwkv_time_mix(
            lp["rwkv"], xin, st["shift_att"], st["wkv"], cfg, policy)
        h = h + out
        cm_out, new_cm = rwkv_mod.rwkv_channel_mix(lp["rwkv"], h,
                                                   st["shift_ffn"], policy)
        h = h + cm_out
        new_cache["self"] = {"shift_att": new_shift, "shift_ffn": new_cm,
                             "wkv": new_wkv}
        return h, new_cache, aux

    if layer.cross_attn and "cross" in cache:
        xq = rms_norm(h, lp["norm_x"], cfg.norm_eps)
        h = h + attn_mod.cross_attn(lp["cross"], xq, cache["cross"]["ck"],
                                    cache["cross"]["cv"], cfg, policy)

    if layer.ffn in ("dense", "moe"):
        h, aux, _ = _apply_ffn(lp, h, layer, cfg, policy)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Full-model forward passes
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, policy: ShardPolicy):
    h = params["embed"][tokens]
    return shard(h.astype(jnp.dtype(cfg.dtype)), policy.act)


def _merge_frontend(params, cfg: ModelConfig, tokens, embeds,
                    policy: ShardPolicy):
    """VLM: project patch embeds and prepend to the token embeddings."""
    h_tok = embed_tokens(params, cfg, tokens, policy)
    if embeds is None or cfg.frontend.kind == "none":
        return h_tok
    proj = jnp.einsum("bpe,ed->bpd", embeds.astype(jnp.dtype(cfg.dtype)),
                      params["frontend_proj"])
    return shard(jnp.concatenate([proj, h_tok], axis=1), policy.act)


def forward_seq(params, cfg: ModelConfig, h, positions, policy: ShardPolicy,
                *, want_cache: bool, seq_cap: int, memory=None,
                remat: bool = False):
    """Runs prefix + scanned period.  Returns (h, caches, aux)."""
    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {}
    if cfg.prefix:
        caches["prefix"] = {}
        for i, layer in enumerate(cfg.prefix):
            lp = params["prefix"][f"layer{i}"]
            h, c, aux = apply_layer_seq(lp, h, layer, cfg, positions, policy,
                                        want_cache=want_cache, seq_cap=seq_cap,
                                        memory=memory)
            caches["prefix"][f"layer{i}"] = c
            aux_total = aux_total + aux
    if cfg.period:
        def body(carry, lp_stack):
            hh, aux_c = carry
            cache_outs = {}
            for i, layer in enumerate(cfg.period):
                hh, c, aux = apply_layer_seq(
                    lp_stack[f"sub{i}"], hh, layer, cfg, positions, policy,
                    want_cache=want_cache, seq_cap=seq_cap, memory=memory)
                cache_outs[f"sub{i}"] = c
                aux_c = aux_c + aux
            return (hh, aux_c), cache_outs

        if remat:
            body = jax.checkpoint(body)
        (h, aux_total), period_caches = jax.lax.scan(
            body, (h, aux_total), params["period"])
        caches["period"] = period_caches
    return h, caches, aux_total


def encode(params, cfg: ModelConfig, frames, policy: ShardPolicy):
    """Encoder for enc-dec models.  frames: [B, F, embed_dim] (stubbed)."""
    h = jnp.einsum("bfe,ed->bfd", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frontend_proj"])
    h = shard(h, policy.act)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1], dtype=jnp.int32),
                                 h.shape[:2])
    enc_layer = LayerSpec(mixer="attn", ffn="dense")

    def body(carry, lp_stack):
        hh, _ = carry
        xin = rms_norm(hh, lp_stack["sub0"]["norm1"], cfg.norm_eps)
        out, _ = attn_mod.gqa_prefill(lp_stack["sub0"]["attn"], xin, positions,
                                      enc_layer, cfg, policy, causal=False)
        hh = hh + out
        hh, _, _ = _apply_ffn(lp_stack["sub0"], hh, enc_layer, cfg, policy)
        return (hh, jnp.zeros((), jnp.float32)), ()

    (h, _), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                             params["encoder"]["period"])
    return rms_norm(h, params["encoder"]["final_norm"], cfg.norm_eps)


def _logits(params, cfg: ModelConfig, h, policy: ShardPolicy):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return shard(logits, policy.logits)


def train_loss(params, cfg: ModelConfig, batch, policy: ShardPolicy = NO_POLICY,
               remat: bool = True):
    """batch: {tokens [B,S], labels [B,S], embeds? [B,P,E], frames? [B,F,E]}."""
    memory = None
    if cfg.encoder is not None:
        memory = encode(params, cfg, batch["frames"], policy)
        h = embed_tokens(params, cfg, batch["tokens"], policy)
    else:
        h = _merge_frontend(params, cfg, batch["tokens"],
                            batch.get("embeds"), policy)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1], dtype=jnp.int32),
                                 h.shape[:2])
    h, _, aux = forward_seq(params, cfg, h, positions, policy,
                            want_cache=False, seq_cap=h.shape[1],
                            memory=memory, remat=remat)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h, policy)
    # align: labels correspond to the *text* tokens (last S_text positions)
    s_text = batch["labels"].shape[1]
    loss = cross_entropy_loss(logits[:, -s_text:], batch["labels"], policy)
    return loss + aux


def prefill(params, cfg: ModelConfig, batch, policy: ShardPolicy = NO_POLICY,
            seq_cap: Optional[int] = None):
    """Returns (last-token logits [B, V], decode cache)."""
    memory = None
    if cfg.encoder is not None:
        memory = encode(params, cfg, batch["frames"], policy)
        h = embed_tokens(params, cfg, batch["tokens"], policy)
    else:
        h = _merge_frontend(params, cfg, batch["tokens"],
                            batch.get("embeds"), policy)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(h.shape[1], dtype=jnp.int32),
                                     h.shape[:2])
    cap = seq_cap or h.shape[1]
    h, caches, _ = forward_seq(params, cfg, h, positions, policy,
                               want_cache=True, seq_cap=cap, memory=memory)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h[:, -1:], policy)[:, 0]
    return logits, caches


def decode_step(params, cfg: ModelConfig, cache, token, positions,
                policy: ShardPolicy = NO_POLICY):
    """token: [B] int32; positions: [B] int32.  Returns (logits [B,V], cache)."""
    h = embed_tokens(params, cfg, token[:, None], policy)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    if cfg.prefix:
        new_cache["prefix"] = {}
        for i, layer in enumerate(cfg.prefix):
            lp = params["prefix"][f"layer{i}"]
            h, c, _ = apply_layer_decode(lp, h, layer, cfg, positions,
                                         cache["prefix"][f"layer{i}"], policy)
            new_cache["prefix"][f"layer{i}"] = c
    if cfg.period:
        # The stacked period cache rides in the scan *carry* and is updated
        # in place with dynamic_update_index_in_dim.  Passing it through
        # xs/ys instead would double-buffer the whole KV cache in HBM
        # (measured: 12.9 GiB temp vs ~2 GiB for stablelm decode_32k).
        def body(carry, xs):
            hh, cache_all = carry
            lp_stack, idx = xs
            for i, layer in enumerate(cfg.period):
                sub = f"sub{i}"
                cache_i = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, idx, 0, keepdims=False), cache_all[sub])
                hh, c_new, _ = apply_layer_decode(
                    lp_stack[sub], hh, layer, cfg, positions, cache_i, policy)
                # write back only the mutable self-cache; cross-attention
                # K/V is read-only during decode
                upd = {k: v for k, v in c_new.items() if k != "cross"}
                cache_all[sub] = dict(cache_all[sub]) if not isinstance(
                    cache_all[sub], dict) else cache_all[sub]
                cache_all = dict(cache_all)
                cache_all[sub] = {
                    **cache_all[sub],
                    **jax.tree.map(
                        lambda a, u: jax.lax.dynamic_update_index_in_dim(
                            a, u, idx, 0),
                        {k: cache_all[sub][k] for k in upd}, upd),
                }
            return (hh, cache_all), ()

        idxs = jnp.arange(cfg.repeats, dtype=jnp.int32)
        (h, period_cache), _ = jax.lax.scan(
            body, (h, cache["period"]), (params["period"], idxs))
        new_cache["period"] = period_cache
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h, policy)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Convenience bundle
# ---------------------------------------------------------------------------

class Model:
    """Thin namespace bundling a config with its plan-derived artifacts."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = model_plan(cfg)

    def init(self, key):
        return init_from_plan(self.plan, key)

    def param_specs(self):
        return specs_from_plan(self.plan)

    def param_shardings(self, mesh):
        return shardings_from_plan(self.plan, mesh)

    def cache_plan(self, batch: int, seq_cap: int, policy: ShardPolicy,
                   enc_len: int = 0):
        return cache_plan(self.cfg, batch, seq_cap, policy, enc_len)
