"""Parameter *plans*: single source of truth for shapes, dtypes, init and
sharding of every parameter.

A plan is a pytree (nested dict) whose leaves are :class:`P`.  From one plan
we derive:
  * ``init_from_plan``      — concrete initialised parameters (smoke tests)
  * ``specs_from_plan``     — ``jax.ShapeDtypeStruct`` stand-ins (dry-run)
  * ``shardings_from_plan`` — ``NamedSharding`` tree for pjit in_shardings

Sharding rules live *on the leaf* (``pspec``), with an optional fallback
``alt`` used when the primary spec would leave mesh devices idle (dimension
smaller than the mesh axis it maps to) — e.g. Mixtral's 8 experts on a
16-way ``model`` axis fall back to tensor-parallel-within-expert.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec


@dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    dtype: str = "bfloat16"
    init: str = "normal"          # normal | zeros | ones | small | identity_decay
    fan_in: Optional[int] = None  # override for scaled-normal init
    pspec: Tuple = ()             # PartitionSpec entries (axis name, tuple, or None)
    alt: Optional[Tuple] = None   # fallback spec when pspec under-utilises mesh


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def _spec_fits(mesh, shape, pspec) -> bool:
    """True if every sharded dim divides evenly by its mesh extent (jit
    argument shardings require exact divisibility, unlike constraints)."""
    for dim, entry in zip(shape, pspec):
        ext = _axis_size(mesh, entry)
        if ext > 1 and (dim < ext or dim % ext != 0):
            return False
    return True


def resolve_pspec(mesh, leaf: P) -> PartitionSpec:
    spec = leaf.pspec
    if leaf.alt is not None and not _spec_fits(mesh, leaf.shape, leaf.pspec):
        spec = leaf.alt
    # trim entries beyond rank, drop axes not in the mesh
    names = set(mesh.axis_names)

    def keep(e, dim):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in names)
            e = kept if kept else None
            if e is None:
                return None
        else:
            e = e if e in names else None
            if e is None:
                return None
        # per-dim divisibility fallback: replicate dims that don't divide
        # (e.g. starcoder2's 36 heads or granite's 49155 vocab on a 16-way
        # axis) — jit in_shardings reject uneven partitions.
        ext = _axis_size(mesh, e)
        if ext > 1 and (dim < ext or dim % ext != 0):
            return None
        return e

    entries = tuple(keep(e, d)
                    for e, d in zip(spec[: len(leaf.shape)], leaf.shape))
    return PartitionSpec(*entries)


def _is_leaf(x):
    return isinstance(x, P)


def specs_from_plan(plan):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype)), plan,
        is_leaf=_is_leaf)


def shardings_from_plan(plan, mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, resolve_pspec(mesh, p)), plan,
        is_leaf=_is_leaf)


def pspecs_from_plan(plan, mesh):
    return jax.tree.map(lambda p: resolve_pspec(mesh, p), plan, is_leaf=_is_leaf)


def _init_leaf(key, p: P):
    dtype = jnp.dtype(p.dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "identity_decay":
        # mamba A_log init: log of [1..d_state] broadcast
        d_state = p.shape[-1]
        a = jnp.tile(jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)),
                     p.shape[:-1] + (1,))
        return a.astype(dtype)
    fan_in = p.fan_in if p.fan_in is not None else (p.shape[0] if p.shape else 1)
    scale = 0.02 if p.init == "small" else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)


def init_from_plan(plan, key):
    leaves, treedef = jax.tree.flatten(plan, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(k, p) for k, p in zip(keys, leaves)])


def count_params(plan) -> int:
    leaves = jax.tree.leaves(plan, is_leaf=_is_leaf)
    return int(sum(np.prod(p.shape) for p in leaves))


def param_bytes(plan) -> int:
    leaves = jax.tree.leaves(plan, is_leaf=_is_leaf)
    return int(sum(np.prod(p.shape) * jnp.dtype(p.dtype).itemsize for p in leaves))
