"""Mixture-of-Experts FFN with sort-based (permutation) token dispatch.

The dispatch is the TPU-idiomatic analogue of MegaBlocks-style grouped
matmul: flatten (token, choice) pairs, stable-sort by expert id, compute
intra-expert slots via searchsorted, scatter into a capacity-bounded
``[E, C, d]`` buffer, run the expert FFN as a batched einsum, and gather
back.  Under pjit the buffer is sharded experts->``model`` (expert
parallelism) and capacity->``data``; the scatter/gather lower to
all-to-all-class collectives.  When E < |model| (e.g. Mixtral's 8 experts
on a 16-way axis) the parameter plan falls back to tensor-parallel within
experts (d_ff on ``model``) via the plan's ``alt`` spec.

DeepSeek-V2 shared experts are computed densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FFNSpec, ModelConfig
from repro.models.common import ShardPolicy, act_fn, shard
from repro.models.params import P


def moe_plan(cfg: ModelConfig, spec: FFNSpec) -> dict:
    d = cfg.d_model
    plan = {
        "router": P((d, spec.num_experts), dtype="float32", init="small",
                     pspec=("data", None)),
        "wi": P((spec.num_experts, d, 2, spec.d_ff), fan_in=d,
                pspec=("model", "data", None, None),
                alt=(None, "data", None, "model")),
        "wo": P((spec.num_experts, spec.d_ff, d), fan_in=spec.d_ff,
                pspec=("model", None, "data"),
                alt=(None, "model", "data")),
    }
    if spec.num_shared_experts:
        sd = spec.d_ff * spec.num_shared_experts
        plan["shared_wi"] = P((d, 2, sd), pspec=("data", None, "model"))
        plan["shared_wo"] = P((sd, d), fan_in=sd, pspec=("model", "data"))
    return plan


def _capacity(num_tokens: int, spec: FFNSpec) -> int:
    c = int(num_tokens * spec.top_k * spec.capacity_factor / spec.num_experts)
    return max(8, min(c, num_tokens))


def moe_ffn(params, x, spec: FFNSpec, cfg: ModelConfig, policy: ShardPolicy):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    tokens = x.reshape(-1, d)                       # [T, d]
    t = tokens.shape[0]
    k = spec.top_k
    e = spec.num_experts

    logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32),
                        params["router"])           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)            # [T, k]
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                   # [E]
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = spec.router_aux_coef * e * jnp.sum(me * ce)

    # ---- permutation dispatch ----
    expert_ids = idx.reshape(-1)                    # [T*k]
    order = jnp.argsort(expert_ids, stable=True)    # sorted (token,choice) pairs
    sorted_eids = expert_ids[order]
    # slot within expert segment = rank - first occurrence index of that expert
    first = jnp.searchsorted(sorted_eids, sorted_eids, side="left")
    slot = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    cap = _capacity(t, spec)
    keep = slot < cap
    src_tok = order // k                            # originating token per pair
    safe_slot = jnp.where(keep, slot, 0)

    # dispatch via int-only scatter + d-wide gather (see combine below for
    # why wide scatters are poison under GSPMD); dropped pairs scatter to a
    # sacrificial extra slot so they can't clobber slot 0
    drop_slot = jnp.where(keep, slot, cap)
    tok_for_slot = jnp.full((e, cap + 1), t, jnp.int32).at[
        sorted_eids, drop_slot].set(src_tok.astype(jnp.int32))[:, :cap]
    tokens_pad = jnp.concatenate(
        [tokens, jnp.zeros((1, d), tokens.dtype)], axis=0)   # row t = zeros
    buf = tokens_pad[tok_for_slot].astype(x.dtype)           # [E, C, d]
    buf = shard(buf, policy.moe_buf)

    # ---- expert FFN: gated MLP as batched einsum over experts ----
    gu = jnp.einsum("ecd,edgf->ecgf", buf, params["wi"])
    h = act_fn(spec.activation)(gu[..., 0, :]) * gu[..., 1, :]
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"])
    out_buf = shard(out_buf, policy.moe_buf)

    # ---- combine: gather back to (token, choice) pairs, weight, sum ----
    # Unsort via an int-only scatter (slot ids, [T*k] s32) + a gather of the
    # d-wide rows, instead of scattering [T*k, d] activations: the wide
    # scatter loses sharding under GSPMD and lowers to replicated
    # all-reduces of the full [T*k, d] buffer (measured 30x32 GiB on
    # jamba train_4k — see EXPERIMENTS.md §Perf H3).
    tok_spec = (policy.act[0], None) if policy.act else None
    slot_unsorted = jnp.zeros((t * k,), jnp.int32).at[order].set(
        safe_slot.astype(jnp.int32))
    keep_unsorted = jnp.zeros((t * k,), jnp.bool_).at[order].set(keep)
    eid_orig = expert_ids.astype(jnp.int32)
    flat_idx = eid_orig * cap + slot_unsorted                  # [T*k]
    picked = shard(out_buf.reshape(e * cap, d)[flat_idx], tok_spec)
    picked = picked * keep_unsorted[:, None].astype(x.dtype)
    per_choice = picked.reshape(t, k, d)
    out = jnp.einsum("tkd,tk->td", per_choice, gates.astype(x.dtype))

    if spec.num_shared_experts:
        gu_s = jnp.einsum("td,dgf->tgf", tokens, params["shared_wi"])
        hs = act_fn(spec.activation)(gu_s[:, 0]) * gu_s[:, 1]
        out = out + jnp.einsum("tf,fd->td", hs, params["shared_wo"])

    return shard(out.reshape(b, s, d), policy.act), aux
