"""RWKV-6 "Finch" mixer: token-shift + data-dependent decay WKV recurrence
[arXiv:2404.05892].

Per-head state S in R^{hd x hd}:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (u = "bonus" for current token)
with w_t = exp(-exp(w0 + lora_w(x_t))) elementwise in (0,1).

Prefill runs a chunked ``lax.scan`` along the sequence; decode is a single
state update.  State per layer:
  shift_att [B, d], shift_ffn [B, d]   (previous token for token-shift)
  wkv       [B, H, hd, hd]             (float32)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ShardPolicy, shard
from repro.models.params import P


def _dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    return cfg.d_model // hd, hd


def rwkv_plan(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    r = cfg.rwkv
    heads, hd = _dims(cfg)
    return {
        # token-shift interpolation weights (r,k,v,g,w)
        "mu": P((5, d), init="small", pspec=(None, "data")),
        "wr": P((d, d), pspec=("data", "model")),
        "wk": P((d, d), pspec=("data", "model")),
        "wv": P((d, d), pspec=("data", "model")),
        "wg": P((d, d), pspec=("data", "model")),
        "wo": P((d, d), pspec=("model", "data")),
        "w0": P((d,), dtype="float32", init="small", pspec=("model",)),
        "w_lora_a": P((d, r.decay_lora), init="small", pspec=("data", None)),
        "w_lora_b": P((r.decay_lora, d), init="small", pspec=(None, "model")),
        "u": P((heads, hd), dtype="float32", init="small", pspec=("model", None)),
        "ln_x": P((d,), dtype="float32", init="zeros", pspec=()),
        # channel-mix
        "cm_mu": P((2, d), init="small", pspec=(None, "data")),
        "cm_wr": P((d, d), pspec=("data", "model")),
        "cm_wk": P((d, r.d_ffn), pspec=("data", "model")),
        "cm_wv": P((r.d_ffn, d), fan_in=r.d_ffn, pspec=("model", "data")),
    }


def rwkv_state_plan(cfg: ModelConfig, batch: int, policy: ShardPolicy) -> dict:
    heads, hd = _dims(cfg)
    sp = policy.state or ()
    return {
        "shift_att": P((batch, cfg.d_model), pspec=tuple(sp[:1]) + (None,)),
        "shift_ffn": P((batch, cfg.d_model), pspec=tuple(sp[:1]) + (None,)),
        "wkv": P((batch, heads, hd, hd), dtype="float32",
                 pspec=tuple(sp[:1]) + ("model", None, None)),
    }


def _token_shift(x, prev, mu):
    """x: [B,S,d]; prev: [B,d] last token of previous chunk."""
    shifted = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return x + mu * (shifted - x)


def _rkvgw(params, x, prev, cfg):
    """Project token-shifted inputs to r,k,v,g and decay w."""
    heads, hd = _dims(cfg)
    mu = params["mu"]
    xr = _token_shift(x, prev, mu[0])
    xk = _token_shift(x, prev, mu[1])
    xv = _token_shift(x, prev, mu[2])
    xg = _token_shift(x, prev, mu[3])
    xw = _token_shift(x, prev, mu[4])
    b, s, _ = x.shape
    r = (xr @ params["wr"]).reshape(b, s, heads, hd)
    k = (xk @ params["wk"]).reshape(b, s, heads, hd)
    v = (xv @ params["wv"]).reshape(b, s, heads, hd)
    g = jax.nn.silu(xg @ params["wg"])
    w_log = params["w0"] + (xw @ params["w_lora_a"]) @ params["w_lora_b"]
    w = jnp.exp(-jnp.exp(w_log.astype(jnp.float32))).reshape(b, s, heads, hd)
    return r, k, v, g, w


def _group_norm(y, weight, heads, eps=1e-5):
    """Per-head LayerNorm of [B,S,H,hd] flattened back to [B,S,d]."""
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mean) * jax.lax.rsqrt(var + eps)
    b, s, h, hd = y.shape
    return yn.reshape(b, s, h * hd) * (1.0 + weight)


def rwkv_time_mix(params, x, state_shift, state_wkv, cfg: ModelConfig,
                  policy: ShardPolicy):
    """x: [B,S,d]. Returns (out, (new_shift, new_wkv))."""
    heads, hd = _dims(cfg)
    r, k, v, g, w = _rkvgw(params, x, state_shift, cfg)
    u = params["u"]

    def step(s_state, inp):
        r_t, k_t, v_t, w_t = inp                          # [B,H,hd] each
        kv = k_t[..., :, None] * v_t[..., None, :]        # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       s_state + u[..., None] * kv)
        s_new = w_t[..., None] * s_state + kv
        return s_new, y

    xs = (jnp.moveaxis(r, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(w, 1, 0))
    s_final, ys = jax.lax.scan(step, state_wkv, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(x.shape[0], x.shape[1], heads, hd)
    y = _group_norm(y, params["ln_x"], heads).astype(x.dtype) * g
    out = y @ params["wo"]
    new_shift = x[:, -1]
    return shard(out, policy.act), (new_shift, shard(s_final, policy.state))


def rwkv_channel_mix(params, x, state_shift, policy: ShardPolicy):
    """RWKV channel-mix FFN.  x: [B,S,d]."""
    mu = params["cm_mu"]
    xr = _token_shift(x, state_shift, mu[0])
    xk = _token_shift(x, state_shift, mu[1])
    r = jax.nn.sigmoid(xr @ params["cm_wr"])
    kk = jnp.square(jax.nn.relu(xk @ params["cm_wk"]))
    out = r * (kk @ params["cm_wv"])
    return shard(out, policy.act), x[:, -1]
