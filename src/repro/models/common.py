"""Shared model components: norms, RoPE, activations, sharding helpers."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from jax._src import mesh as _mesh_lib


def in_mesh_context() -> bool:
    m = _mesh_lib.thread_resources.env.physical_mesh
    return not m.empty


def shard(x, spec: Optional[Tuple]):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    if spec is None or not in_mesh_context():
        return x
    entries = tuple(spec[: x.ndim]) + (None,) * max(0, x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*entries))


@dataclass(frozen=True)
class ShardPolicy:
    """Activation sharding policy, resolved per (arch x input-shape x mesh).

    Each field is a PartitionSpec-style tuple (or None = no constraint).
    ``batch`` names the mesh axes carrying the batch dimension.
    """
    act: Optional[Tuple] = None          # [B, S, d]
    heads: Optional[Tuple] = None        # [B, S, H, hd]
    kv_cache: Optional[Tuple] = None     # [B, S, KV, hd]
    mla_cache: Optional[Tuple] = None    # [B, S, ckv(+rope)]
    state: Optional[Tuple] = None        # [B, d_inner, ...] recurrent state
    moe_buf: Optional[Tuple] = None      # [E, C, d]
    logits: Optional[Tuple] = None       # [B, S, V] / [B, V]


NO_POLICY = ShardPolicy()


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs      # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                            # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def gated_ffn(x, w_in, w_out, activation: str, policy: ShardPolicy):
    """w_in: [d, 2, ff] (gate, up); w_out: [ff, d]."""
    gu = jnp.einsum("bsd,dcf->bscf", x, w_in)
    gate, up = gu[..., 0, :], gu[..., 1, :]
    h = act_fn(activation)(gate) * up
    out = jnp.einsum("bsf,fd->bsd", h, w_out)
    return shard(out, policy.act)


def cross_entropy_loss(logits, labels, policy: ShardPolicy):
    """logits: [B, S, V] (possibly vocab-sharded), labels: [B, S] int32."""
    logits = shard(logits.astype(jnp.float32), policy.logits)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    tgt = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0] + m[..., 0]
    return jnp.mean(lse - tgt)
