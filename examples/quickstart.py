"""Quickstart: the three layers of the framework in ~60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. model substrate  — build any assigned architecture, run a train step
2. serving engine   — continuous batching with TTFT tracking
3. controller       — the paper's multi-tenancy control loop on the
                      discrete-event cluster
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models.model import Model, train_loss
from repro.serving.engine import ServingEngine
from repro.serving.request import Request

# ---------------------------------------------------------- 1. model layer
print("== 1. model substrate ==")
cfg = reduced(get_config("mixtral_8x7b"))        # MoE + sliding window
model = Model(cfg)
params = model.init(jax.random.key(0))
batch = {
    "tokens": jnp.ones((2, 32), jnp.int32),
    "labels": jnp.ones((2, 32), jnp.int32),
}
loss = jax.jit(lambda p, b: train_loss(p, cfg, b, remat=False))(params, batch)
print(f"  {cfg.name}: one train step, loss = {float(loss):.3f}")

# -------------------------------------------------------- 2. serving layer
print("== 2. serving engine (continuous batching) ==")
eng = ServingEngine(reduced(get_config("stablelm_3b")), max_slots=4,
                    seq_cap=64)
for i in range(6):
    eng.submit(Request(req_id=i, tenant="T1", prompt_len=16,
                       max_new_tokens=4, arrival=0.0, slo_ms=200.0))
now = 0.0
while eng.has_work():
    rep = eng.step()
    now += max(rep.compute_s, 1e-4)
    eng.finalize_step(rep, now)
print(f"  served 6 requests, p99 TTFT = "
      f"{eng.metrics.latency.p99()*1e3:.1f} ms (virtual)")

# ----------------------------------------------------- 3. controller layer
print("== 3. multi-tenancy controller (paper core) ==")
from repro.core.controller import Controller, ControllerConfig
from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams, default_schedule


def factory(sim):
    c = Controller(sim.topo, sim.lattice, sim, ControllerConfig())
    sim.register_tenants(c)      # the paper 3-tenant registry, as data
    return c


p = SimParams(duration_s=600.0, seed=0, schedule=default_schedule(600.0))
static = ClusterSim(p).run()
controlled = ClusterSim(p, factory).run()
print(f"  static     : p99 = {static.p99*1e3:5.1f} ms, "
      f"miss = {static.miss_rate*100:4.1f}%")
print(f"  controlled : p99 = {controlled.p99*1e3:5.1f} ms, "
      f"miss = {controlled.miss_rate*100:4.1f}%  "
      f"actions = {controlled.actions}")
print("done.")
