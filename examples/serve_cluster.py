"""End-to-end serving driver (the paper's kind of workload): serve a small
model with batched requests under PCIe-class interference, with and
without the controller — the Table 2 scenario at example scale.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.llm_ttft import run

print("serving OLMo-2 (reduced) under T2/T3 interference, 600 virtual s...")
static = run(duration=600.0, with_controller=False, verbose=False)
print(f"  static MIG : TTFT p99 = {static['ttft_p99_ms']:6.1f} ms, "
      f"miss = {static['miss_rate']*100:4.1f}%, "
      f"thr = {static['throughput_rps']:.2f} rps")

full = run(duration=600.0, with_controller=True, verbose=False)
norm = full["throughput_rps"] / max(static["throughput_rps"], 1e-9)
print(f"  controlled : TTFT p99 = {full['ttft_p99_ms']:6.1f} ms, "
      f"miss = {full['miss_rate']*100:4.1f}%, "
      f"norm thr = {norm:.3f}")
print(f"  controller actions: {full['actions']}")
print(f"  TTFT p99 reduction: "
      f"{(1 - full['ttft_p99_ms']/max(static['ttft_p99_ms'],1e-9))*100:.1f}% "
      f"(paper Table 2: ~14%)")
