"""End-to-end serving driver (the paper's kind of workload): serve small
models with batched requests under PCIe-class interference, with and
without the controller — the Table 2 scenario at example scale, then the
multi-tenant generalization: two SLO tenants, each with two engine
replicas, sharing one fabric and one controller.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.llm_ttft import run

print("== 1. single tenant (paper Table 2 scenario, reduced scale) ==")
print("serving OLMo-2 (reduced) under T2/T3 interference, 600 virtual s...")
# auto_calibrate: derive the 7B compute scale from this host's measured
# prefill so the operating point matches the paper on any CPU speed
static = run(duration=600.0, with_controller=False, verbose=False,
             auto_calibrate=True)
print(f"  static MIG : TTFT p99 = {static['ttft_p99_ms']:6.1f} ms, "
      f"miss = {static['miss_rate']*100:4.1f}%, "
      f"thr = {static['throughput_rps']:.2f} rps")

full = run(duration=600.0, with_controller=True, verbose=False,
           auto_calibrate=True)
norm = full["throughput_rps"] / max(static["throughput_rps"], 1e-9)
print(f"  controlled : TTFT p99 = {full['ttft_p99_ms']:6.1f} ms, "
      f"miss = {full['miss_rate']*100:4.1f}%, "
      f"norm thr = {norm:.3f}")
print(f"  controller actions: {full['actions']}")
print(f"  TTFT p99 reduction: "
      f"{(1 - full['ttft_p99_ms']/max(static['ttft_p99_ms'],1e-9))*100:.1f}% "
      f"(paper Table 2: ~14%)")

print()
print("== 2. two SLO tenants x two replicas, one controller ==")
from repro.launch.serve import serve

out = serve(arch="stablelm_3b", requests=16, qps=6.0, prompt_len=32,
            max_new=4, slots=4, num_tenants=2, replicas=2,
            interfere=True, with_controller=True, seed=0)
print(f"  arbiter peak units/GPU: {out.get('arbiter_max_units', 0)} "
      f"(budget 7)")
