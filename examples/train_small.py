"""Train a ~small model for a few hundred steps on CPU (deliverable b).

    PYTHONPATH=src python examples/train_small.py [--steps 200]

Uses the reduced Jamba config — the most heterogeneous assigned arch
(Mamba + attention + MoE) — so one run exercises every mixer/FFN path.
"""
import argparse

from repro.configs.base import get_config, reduced
from repro.training.data import SyntheticTokenPipeline
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

cfg = reduced(get_config("jamba_v0_1_52b"))
pipe = SyntheticTokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)
print(f"training {cfg.name} ({cfg.num_layers} layers: mamba+attn+moe) "
      f"for {args.steps} steps")
res = train(
    cfg, iter(pipe), args.steps,
    AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    log_fn=lambda i, loss, gn: print(f"  step {i:4d} loss={loss:.4f}"),
    log_every=20)
print(f"loss: {res.losses[0]:.4f} -> {res.losses[-1]:.4f}")
assert res.losses[-1] < res.losses[0], "training failed to reduce loss"
print("OK")
