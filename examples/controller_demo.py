"""Controller walkthrough (paper Fig 3a analogue): watch the escalation
Guardrails -> Placement -> MIG across interference bursts, with the audit
log and the post-change validation verdicts.

    PYTHONPATH=src python examples/controller_demo.py
"""
import numpy as np

from repro.core.controller import Controller, ControllerConfig
from repro.sim.cluster import ClusterSim
from repro.sim.params import SimParams, default_schedule

DURATION = 1500.0


def factory(sim):
    c = Controller(sim.topo, sim.lattice, sim, ControllerConfig())
    sim.register_tenants(c)      # the paper 3-tenant registry, as data
    return c


p = SimParams(duration_s=DURATION, seed=1, schedule=default_schedule(DURATION))
sim = ClusterSim(p, factory)
res = sim.run()

print("interference schedule:")
for w in p.schedule:
    print(f"  {w.tenant} active {w.start:7.1f}s - {w.end:7.1f}s")

print("\ncontroller timeline (escalation per burst):")
for t, action in res.timeline:
    print(f"  t={t:8.1f}s  {action}")

print("\naudit log decisions:")
for d in sim.controller.audit.decisions:
    extra = f" validated={d.validated}" if d.validated is not None else ""
    print(f"  t={d.time:8.1f}s {d.action:12s} {d.tenant:3s} "
          f"p99={d.signal_summary.get('p99', 0)*1e3:6.2f}ms{extra}")

print(f"\nfinal: p99={res.p99*1e3:.2f} ms, miss={res.miss_rate*100:.2f}%, "
      f"throughput={res.throughput_rps:.2f} rps "
      f"({res.dropped} load-shed during reconfigs)")
t1 = sim.tenant("T1")
print(f"T1 ended on {t1.replicas[0].slot.key} with profile "
      f"{t1.profile.name}")
